"""End-to-end serving driver (the paper's kind is inference): serve a small
LM with batched requests, comparing fp vs packed sub-byte weights.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs.qwen2p5_3b import smoke_config
from repro.models.api import build
from repro.nn.layers import QuantConfig, pack_dense_weights
from repro.serve.engine import Engine, Request


def fill_packed(qp, fp):
    if isinstance(qp, dict) and "w_packed" in qp:
        w = fp["w"]
        if w.ndim == 3:
            packed, scale = jax.vmap(lambda ww: pack_dense_weights(ww, 4))(w)
        else:
            packed, scale = pack_dense_weights(w, 4)
        return dict(qp, w_packed=packed, w_scale=scale)
    if isinstance(qp, dict):
        return {k: fill_packed(qp[k], fp[k]) if k in fp else qp[k]
                for k in qp}
    return qp


cfg_fp = smoke_config()
model_fp = build(cfg_fp)
params_fp = model_fp.init(jax.random.PRNGKey(0))

cfg_q = dataclasses.replace(
    cfg_fp, quant=QuantConfig(mode="int", w_bits=4, a_bits=8),
    kv_quant_bits=8)
model_q = build(cfg_q)
params_q = fill_packed(model_q.init(jax.random.PRNGKey(0)), params_fp)

reqs = [Request(prompt=np.array([2 + i, 40 + i, 7], np.int32),
                max_new_tokens=8) for i in range(4)]

for name, model, params in [("fp32", model_fp, params_fp),
                            ("w4a8+int8kv", model_q, params_q)]:
    eng = Engine(model, params, batch_size=4, max_len=32)
    t0 = time.time()
    out = eng.generate([dataclasses.replace(r) for r in reqs])
    dt = time.time() - t0
    toks = sum(len(r.out) for r in out)
    print(f"[{name}] {toks} tokens in {dt:.2f}s; "
          f"sample: {out[0].out.tolist()}")

p_fp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params_fp))
p_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params_q))
print(f"weight bytes: fp32 {p_fp}  packed-w4 {p_q}  ({p_fp / p_q:.1f}x)")
