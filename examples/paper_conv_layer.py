"""The paper's benchmark: conv layers (16x16x32 and 32x32x32 inputs,
64x3x3x32 filters) at 8/4/2-bit, full integer pipeline (implicit-GEMM
gather -> packed MatMul -> BN -> QNT/ACT). The `pallas_interpret` backend
is the fused implicit-GEMM Pallas kernel (no HBM im2col tensor); the
`xla` backend is the explicit im2col + XLA GEMM fallback — bit-exact
against each other (see repro.kernels.api for the backend registry).

    PYTHONPATH=src python examples/paper_conv_layer.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (QuantSpec, quantize, calibrate_weight,
                        calibrate_activation)
from repro.kernels.api import qconv
from repro.kernels.qconv import quantize_conv

rng = np.random.default_rng(0)
for H, W in [(16, 16), (32, 32)]:
    x = np.maximum(rng.normal(size=(1, H, W, 32)), 0).astype(np.float32)
    w = rng.normal(size=(3, 3, 32, 64)).astype(np.float32) * 0.08
    bn_s = rng.normal(size=(64,)).astype(np.float32) * 0.05 + 0.3
    bn_b = np.zeros((64,), np.float32)
    macs = H * W * 64 * 3 * 3 * 32
    for bits in (8, 4, 2):
        sw = calibrate_weight(jnp.asarray(w), bits)
        sx = calibrate_activation(x, bits, 100.0)
        sy = QuantSpec.activation(bits, 8.0)
        qp = quantize_conv(jnp.asarray(w), sw, bn_s, bn_b, sx, sy, 1, 1)
        xq = quantize(jnp.asarray(x), sx)
        yk = qconv(qp, xq, backend="pallas_interpret")
        yj = qconv(qp, xq, backend="xla")
        assert np.array_equal(np.asarray(yk), np.asarray(yj))
        wbytes = qp.gemm.w_packed.size
        print(f"conv {H}x{W}x32 {bits}-bit: out {tuple(yk.shape)} "
              f"{macs} MACs, packed weights {wbytes}B "
              f"({8 // bits}x compression), fused==im2col BIT-EXACT")
print("paper pipeline reproduced (see benchmarks/fig11 for perf terms)")
