"""Quickstart: the paper's technique in 30 lines.

Quantize a linear layer to 4-bit (nibble) integer images, run the packed
Pallas GEMM with the fused BN+QNT/ACT epilogue, and check it against the
float pipeline.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (QuantSpec, quantize, dequantize, quantize_linear,
                        calibrate_weight, calibrate_activation)
from repro.kernels.api import qdot

rng = np.random.default_rng(0)
K, N, M = 512, 128, 64

# a float layer: y = relu(bn_scale * (x @ w) + bn_bias)
w = rng.normal(size=(K, N)).astype(np.float32) * 0.05
x = np.maximum(rng.normal(size=(M, K)), 0).astype(np.float32)
bn_s = rng.normal(size=(N,)).astype(np.float32) * 0.1 + 1.0
bn_b = rng.normal(size=(N,)).astype(np.float32) * 0.01
y_float = np.maximum((x @ w) * bn_s + bn_b, 0)

# 1. calibrate 4-bit grids (weights symmetric signed, activations unsigned)
sw = calibrate_weight(jnp.asarray(w), bits=4)
sx = calibrate_activation(x, bits=4)
sy = calibrate_activation(y_float, bits=4)

# 2. build the deployable artifact: chunk-planar packed weights + integer
#    BN/requant params (eq. 1-4 of the paper)
qparams = quantize_linear(jnp.asarray(w), sw, bn_s, bn_b, sx, sy)
print(f"packed weights: {qparams.w_packed.shape} int8 "
      f"({qparams.w_packed.size / (K * N):.2%} of unpacked bytes)")

# 3. integer forward: quantize activations -> packed GEMM -> 4-bit output.
#    backend=None would resolve pallas-on-TPU / xla-elsewhere; we ask for
#    the Pallas interpreter explicitly so the walkthrough runs anywhere.
x_hat = quantize(jnp.asarray(x), sx)
y_hat = qdot(qparams, x_hat, backend="pallas_interpret")
y_int = np.asarray(dequantize(y_hat, sy))

rel = np.abs(y_int - y_float).max() / np.abs(y_float).max()
print(f"4-bit integer pipeline vs float: max rel err {rel:.3f}")
assert rel < 0.35  # W4A4 noise on random data
print("OK — see examples/paper_conv_layer.py for the full conv pipeline")
