"""Vision quickstart: a paper-class quantized CNN, end to end in integers.

Builds the MLPerf-Tiny-style ResNet-8, calibrates it on random images,
packs a uniform W4A8 artifact, and runs the integer-only forward through
two kernel backends of the registry (`repro.kernels.api`) — bit-exact
against each other, with uint8 integer images at every layer boundary and
int32 accumulation inside. Swap --net / bits / plan via the full CLI:
``python -m repro.launch.vision --net resnet8 --smoke --budget auto``.

    PYTHONPATH=src python examples/vision_quickstart.py
"""
import numpy as np

from repro.vision import (forward_int, get_vision_config, init_fp,
                          collect_absmax, quantize_input, quantize_net,
                          vision_artifact_bytes)

rng = np.random.default_rng(0)
cfg = get_vision_config("resnet8", smoke=True)
params = init_fp(cfg, seed=0)
images = rng.uniform(0, 1, size=(4, *cfg.in_hw, cfg.in_ch)).astype(
    np.float32)

absmax = collect_absmax(cfg, params, [images])
qnet = quantize_net(cfg, params, absmax, default_w_bits=4)
x_hat = quantize_input(qnet, images)
print(f"{cfg.name}: {len(qnet.qlayers)} layers, uniform W4A8, "
      f"packed artifact {vision_artifact_bytes(qnet):,} bytes")

logits_xla = forward_int(qnet, x_hat, backend="xla")
logits_pal = forward_int(qnet, x_hat, backend="pallas_interpret")
assert np.array_equal(np.asarray(logits_xla), np.asarray(logits_pal))
preds = np.asarray(logits_xla).argmax(-1)
print(f"int32 logits {tuple(logits_xla.shape)}, preds {preds.tolist()}, "
      "xla == pallas_interpret BIT-EXACT")
print("quantized CNN pipeline reproduced (see benchmarks/e2e_networks.py "
      "for the network-level perf sweep)")
