"""QAT quickstart: fake-quant train a digit CNN, fold it to integers,
and evaluate the deployed artifact on the integer path.

The whole paper loop in one script, CPU-sized (<2 min):

  1. train the smoke `qat-cnn` with W4 fake-quant weights and EMA-tracked
     A8 activation ranges (`repro.qat` — STE gradients through the exact
     `core.quantize` grids the deployment packs);
  2. fold the trained model into the integer artifact (`quantize_net`,
     eqs. 1-4) — `fold_check` proves the weight grids fold bit-exact,
     no post-training recalibration anywhere;
  3. evaluate BOTH paths on held-out digits: the fake-quant forward the
     net trained with, and `forward_int` — the uint{8,4,2} arithmetic
     the kernels execute. The two accuracies agree because training
     simulated exactly what deployment runs.

    PYTHONPATH=src python examples/train_qat.py [--steps 300] [--w-bits 4]
"""
import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--w-bits", type=int, default=4, choices=(8, 4, 2))
ap.add_argument("--batch", type=int, default=64)
args = ap.parse_args()

from repro.qat import (QATConfig, deploy, evaluate_int, fold_check,
                       train_qat)
from repro.qat.data import make_dataset
from repro.qat.evaluate import evaluate_fq
from repro.vision.configs import get_vision_config
from repro.vision.models import streamed_weight_bytes

cfg = get_vision_config("qat-cnn", smoke=True)
train_data = make_dataset("synthetic", split="train", seed=0)
test_data = make_dataset("synthetic", split="test", seed=0)

# -- 1. fake-quant training ------------------------------------------------
qc = QATConfig(steps=args.steps, batch=args.batch, w_bits=args.w_bits,
               a_bits=8, seed=0, log_every=max(args.steps // 6, 1))
result = train_qat(cfg, train_data, qc)
for r in result.log:
    print(f"step {r['step']:4d}  loss {r['loss']:.4f}  acc {r['acc']:.3f}")
assert result.log[-1]["loss"] < result.log[0]["loss"], \
    "training did not reduce the loss"

# -- 2. fold to the integer artifact ---------------------------------------
fold_check(result)   # every weight grid folds bit-exact, else AssertionError
qnet = deploy(result)
print(f"\ndeployed W{args.w_bits}A8: "
      f"{streamed_weight_bytes(qnet)} packed bytes/forward")

# -- 3. integer-path evaluation --------------------------------------------
fq = evaluate_fq(result, test_data.batches(100, 5))
iq = evaluate_int(qnet, test_data.batches(100, 5))
print(f"fake-quant accuracy : {fq['accuracy']:.4f} "
      f"({fq['correct']}/{fq['n']})")
print(f"integer-path accuracy: {iq['accuracy']:.4f} "
      f"({iq['correct']}/{iq['n']})")
assert iq["accuracy"] > 0.5, "integer-path accuracy collapsed"
assert abs(iq["accuracy"] - fq["accuracy"]) < 0.05, \
    "trained (fake-quant) and deployed (integer) paths disagree"
print("OK: trained fake-quant model folded losslessly to the integer path")
