"""QAT training driver with fault-tolerant runtime: trains an LM with
4-bit fake-quant weights (STE), checkpoint/restart, straggler monitoring.

Default is a CPU-sized model; --full trains the ~100M-param config (slow
on CPU — intended for a real accelerator slice).

    PYTHONPATH=src python examples/train_qat.py [--steps 60] [--full]
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.api import build
from repro.nn.layers import QuantConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig
from repro.train.step import TrainStepConfig, make_train_fns

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--full", action="store_true",
                help="~100M params (accelerator-sized)")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

if args.full:  # ~100M params
    cfg = ModelConfig(name="qat-100m", family="lm", n_layers=12,
                      d_model=768, n_heads=12, kv_heads=12, d_ff=3072,
                      vocab=32768)
else:
    cfg = ModelConfig(name="qat-tiny", family="lm", n_layers=4,
                      d_model=128, n_heads=4, kv_heads=4, d_ff=512,
                      vocab=1024, remat=False)
cfg = dataclasses.replace(
    cfg, quant=QuantConfig(mode="fake", w_bits=4, a_bits=8))

model = build(cfg)
mesh = make_host_mesh()
shape = ShapeConfig("t", args.seq, args.batch, "train")
init_fn, step, shards = make_train_fns(
    model, mesh, shape,
    TrainStepConfig(opt=OptConfig(lr=1e-3, warmup=20,
                                  total_steps=args.steps)))
data = SyntheticLM(cfg.vocab, args.batch, args.seq, seed=0)
ckpt_dir = tempfile.mkdtemp(prefix="qat_ckpt_")
trainer = Trainer(init_fn, jax.jit(step), data,
                  TrainerConfig(total_steps=args.steps, ckpt_every=20,
                                ckpt_dir=ckpt_dir))
state, log = trainer.run(jax.random.PRNGKey(0))
print(f"step {log[0]['step']}: loss {log[0]['loss']:.3f}")
print(f"step {log[-1]['step']}: loss {log[-1]['loss']:.3f} "
      f"(median step {trainer.monitor.median * 1e3:.0f} ms, "
      f"stragglers flagged: {trainer.monitor.flags})")
print(f"checkpoints at {ckpt_dir}")
assert log[-1]["loss"] < log[0]["loss"]
print("QAT model trained — deploy by packing weights "
      "(examples/serve_quantized.py)")
